"""VectorService contracts: named-collection routing on one shared core,
geometry-keyed compile-cache sharing, database persistence (db.json),
write forwarding to mutable backends, and lifecycle/context management."""
import json
import os

import numpy as np
import pytest

from repro.core import (
    IndexFormatError,
    MemoryMode,
    MutableIndex,
    PageANNConfig,
    PageANNIndex,
    SearchParams,
)
from repro.core import persist
from repro.data.pipeline import clustered_vectors, query_vectors
from repro.serve import BatchingEngine, VectorService
from repro.serve.compile_cache import CompileCache, geometry_of

N, D = 600, 32


def _cfg(**kw) -> PageANNConfig:
    base = dict(
        dim=D, graph_degree=12, build_beam=24, pq_subspaces=8,
        lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )
    base.update(kw)
    return PageANNConfig(**base)


@pytest.fixture(scope="module")
def corpus_a():
    return clustered_vectors(N, D, num_clusters=16, seed=0)


@pytest.fixture(scope="module")
def corpus_b():
    return clustered_vectors(N, D, num_clusters=16, seed=42)


@pytest.fixture(scope="module")
def index_a(corpus_a):
    return PageANNIndex.build(corpus_a, _cfg())


@pytest.fixture(scope="module")
def index_b(corpus_b):
    return PageANNIndex.build(corpus_b, _cfg())


@pytest.fixture()
def queries(corpus_a):
    return query_vectors(corpus_a, 6, seed=3)


def _ids(rows):
    return np.stack([r.result.ids for r in rows])


# ---------------------------------------------------------------- routing
def test_routing_matches_direct_search(index_a, index_b, queries):
    """Each collection's requests reach ITS index: interleaved submits to
    two collections demux back to exactly what each index returns
    directly."""
    with VectorService(batch_size=4) as svc:
        svc.create_collection("a", index_a)
        svc.create_collection("b", index_b)
        assert svc.list_collections() == ("a", "b")
        futs = [
            svc.submit("a" if i % 2 == 0 else "b", q, k=5)
            for i, q in enumerate(queries)
        ]
        svc.flush()
        rows = [f.result(timeout=120) for f in futs]
    want_a = index_a.search(queries[0::2], k=5)
    want_b = index_b.search(queries[1::2], k=5)
    np.testing.assert_array_equal(_ids(rows[0::2]), want_a.ids)
    np.testing.assert_array_equal(_ids(rows[1::2]), want_b.ids)


def test_bit_identical_to_independent_engines(index_a, index_b, queries):
    """Acceptance: two same-geometry collections behind ONE VectorService
    return results bit-identical to two independent BatchingEngine
    .from_index instances, while the second collection's warm groups
    compile zero new executables."""
    with VectorService(batch_size=4) as svc:
        svc.create_collection("a", index_a, k=5)
        rows_a = svc.search("a", queries)
        m_after_a = svc.metrics()
        svc.create_collection("b", index_b, k=5)
        rows_b = svc.search("b", queries)
        m_after_b = svc.metrics()

    with BatchingEngine.from_index(index_a, k=5, batch_size=4) as eng_a:
        solo_a = eng_a.search(queries)
    with BatchingEngine.from_index(index_b, k=5, batch_size=4) as eng_b:
        solo_b = eng_b.search(queries)

    for rows, solo in ((rows_a, solo_a), (rows_b, solo_b)):
        for field in ("ids", "dists", "ios", "hops", "cache_hits"):
            got = np.stack([np.asarray(getattr(r.result, field)) for r in rows])
            want = np.stack(
                [np.asarray(getattr(r.result, field)) for r in solo]
            )
            np.testing.assert_array_equal(got, want, err_msg=field)

    # the shared compile cache: collection b's dispatches re-used a's
    # executable (same geometry) — zero new compiles, all hits
    assert m_after_a.compile_misses > 0
    assert m_after_b.compile_misses == m_after_a.compile_misses
    assert m_after_b.compiled_executables == m_after_a.compiled_executables
    assert m_after_b.compile_hits > m_after_a.compile_hits


def test_same_geometry_keys_equal_distinct_differ(index_a, index_b, corpus_a):
    ga, gb = geometry_of(index_a), geometry_of(index_b)
    assert ga == gb  # same cfg + corpus size -> same compiled geometry
    small = PageANNIndex.build(corpus_a[:300], _cfg())
    assert geometry_of(small) != ga  # fewer pages -> its own executables


def test_create_from_config_builds(corpus_a, queries):
    with VectorService(batch_size=4) as svc:
        handle = svc.create_collection("built", _cfg(), corpus_a, k=5)
        rows = handle.search(queries)
        assert _ids(rows).shape == (len(queries), 5)
    with pytest.raises(ValueError, match="needs vectors"):
        VectorService().create_collection("x", _cfg())


def test_handles_and_registry(index_a):
    svc = VectorService(batch_size=2)
    h = svc.create_collection("a", index_a)
    assert h.name == "a" and h.index is index_a
    assert svc.collection("a").index is index_a
    assert "a" in svc and len(svc) == 1 and list(svc) == ["a"]
    with pytest.raises(KeyError):
        svc.collection("nope")
    with pytest.raises(ValueError, match="already exists"):
        svc.create_collection("a", index_a)
    with pytest.raises(TypeError, match="VectorIndex"):
        svc.create_collection("bad", object())
    svc.close()


@pytest.mark.parametrize(
    "name", ["", "-x", ".hidden", "a/b", "a b", "x" * 65, 7]
)
def test_invalid_collection_names(index_a, name):
    with VectorService() as svc:
        with pytest.raises(ValueError, match="collection name"):
            svc.create_collection(name, index_a)


def test_drop_dispatches_pending_then_unroutes(index_a, index_b, queries):
    with VectorService(batch_size=64) as svc:  # big batch: stays pending
        svc.create_collection("a", index_a, k=4)
        svc.create_collection("b", index_b, k=4)
        fut = svc.submit("a", queries[0])
        svc.drop("a")
        # the pending request was dispatched (padded), not abandoned
        np.testing.assert_array_equal(
            fut.result(timeout=120).result.ids,
            index_a.search(queries[:1], k=4).ids[0],
        )
        assert svc.list_collections() == ("b",)
        with pytest.raises(KeyError):
            svc.submit("a", queries[0])
        with pytest.raises(KeyError):
            svc.drop("a")
        # the survivor keeps serving
        assert _ids(svc.search("b", queries[:2])).shape == (2, 4)


def test_writes_route_to_mutable_collection(index_a, index_b, queries):
    with VectorService(batch_size=4) as svc:
        svc.create_collection("frozen", index_a, k=3)
        svc.create_collection("mut", MutableIndex(index_b), k=3)
        new_ids = svc.insert("mut", queries[:2])
        assert new_ids.shape == (2,)
        # the inserted vectors are immediately retrievable — and only
        # through the mutable collection
        rows = svc.search("mut", queries[:2], k=1)
        np.testing.assert_array_equal(_ids(rows)[:, 0], new_ids)
        assert svc.delete("mut", new_ids) == 2
        with pytest.raises(RuntimeError, match="insert"):
            svc.insert("frozen", queries[:1])
        with pytest.raises(RuntimeError, match="delete"):
            svc.delete("frozen", [0])
        with pytest.raises(RuntimeError, match="compact"):
            svc.compact("frozen")
        m = svc.metrics()
        assert m.inserts == 2 and m.deletes == 2


# ------------------------------------------------------------- persistence
def test_database_round_trip(tmp_path, index_a, index_b, queries):
    db = str(tmp_path / "db")
    with VectorService(batch_size=4) as svc:
        svc.create_collection("alpha", index_a, k=5)
        svc.create_collection("beta", MutableIndex(index_b), k=5)
        svc.insert("beta", queries[:1])  # dirty state must round-trip too
        want_a = _ids(svc.search("alpha", queries))
        want_b = _ids(svc.search("beta", queries))
        svc.save(db)

    assert persist.is_database_dir(db)
    doc = persist.read_db_manifest(db)
    assert sorted(doc["collections"]) == ["alpha", "beta"]

    with VectorService.load(db, batch_size=4) as svc2:
        assert svc2.list_collections() == ("alpha", "beta")
        assert isinstance(svc2.collection("beta").index, MutableIndex)
        np.testing.assert_array_equal(
            _ids(svc2.search("alpha", queries, k=5)), want_a
        )
        np.testing.assert_array_equal(
            _ids(svc2.search("beta", queries, k=5)), want_b
        )


def test_attach_registers_saved_artifact(tmp_path, index_a, queries):
    art = str(tmp_path / "idx")
    index_a.save(art)
    with VectorService(batch_size=4) as svc:
        svc.attach("fromdisk", art, k=5)
        got = _ids(svc.search("fromdisk", queries))
    np.testing.assert_array_equal(got, index_a.search(queries, k=5).ids)


def test_db_manifest_format_errors(tmp_path, index_a):
    db = str(tmp_path / "db")
    persist.save_database({"only": index_a}, db)
    path = os.path.join(db, persist.DB_MANIFEST)

    with open(path) as f:
        doc = json.load(f)
    doc["version"] = persist.DB_VERSION + 1
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(IndexFormatError, match="upgrade"):
        persist.load_database(db)

    with open(path, "w") as f:
        f.write("{ not json")
    with pytest.raises(IndexFormatError, match="not valid JSON"):
        persist.load_database(db)

    os.remove(path)
    with pytest.raises(FileNotFoundError):
        persist.load_database(db)
    assert not persist.is_database_dir(db)


def test_db_manifest_rejects_tampered_paths(tmp_path, index_a):
    """Artifact paths come from validated names, never manifest values:
    a db.json steering a collection outside collections/ is refused."""
    db = str(tmp_path / "db")
    persist.save_database({"ok": index_a}, db)
    path = os.path.join(db, persist.DB_MANIFEST)
    with open(path) as f:
        doc = json.load(f)
    doc["collections"]["ok"] = "../../somewhere/else"
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(IndexFormatError, match="unexpected path"):
        persist.load_database(db)


def test_db_manifest_rejects_wrong_format(tmp_path, index_a):
    # an index directory is not a database directory, and vice versa
    art = str(tmp_path / "idx")
    index_a.save(art)
    with open(os.path.join(art, persist.DB_MANIFEST), "w") as f:
        json.dump(dict(format="something.else", version=1, collections={}), f)
    with pytest.raises(IndexFormatError, match="not a repro.vector_database"):
        persist.read_db_manifest(art)


# -------------------------------------------------------------- lifecycle
def test_context_manager_and_idempotent_close(index_a):
    with VectorService(batch_size=2) as svc:
        svc.create_collection("a", index_a)
    with pytest.raises(RuntimeError, match="closed"):
        svc.create_collection("b", index_a)
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit("a", np.zeros(D, np.float32))
    svc.close()  # idempotent
    svc.close()


def test_explicit_shared_compile_cache(index_a, index_b, queries):
    """Two SERVICES handed the same CompileCache share warm executables —
    the cache is process-scoped state, not service-private."""
    cache = CompileCache()
    with VectorService(batch_size=4, compile_cache=cache) as s1:
        s1.create_collection("a", index_a, k=5)
        s1.search("a", queries)
    misses_after_s1 = cache.stats().misses
    assert misses_after_s1 > 0
    with VectorService(batch_size=4, compile_cache=cache) as s2:
        s2.create_collection("b", index_b, k=5)
        s2.search("b", queries)
    assert cache.stats().misses == misses_after_s1
    assert cache.stats().hits > 0
