"""train_step behaviour: metrics, microbatch equivalence, state updates."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_arch
from repro.data.pipeline import TokenPipeline
from repro.train.step import init_train_state, make_serve_step, make_train_step


def _setup(arch_id="granite-3-2b", num_mb=1, batch=4, seq=32):
    cfg = get_arch(arch_id, smoke=True)
    shape = ShapeConfig("t", seq, batch, "train", num_microbatches=num_mb)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg, shape)
    b = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    return cfg, shape, state, b


def test_train_step_updates_params_and_metrics():
    cfg, shape, state, batch = _setup()
    step_fn = jax.jit(make_train_step(cfg, shape))
    new_state, metrics = step_fn(state, batch)
    assert int(new_state.step) == 1
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    deltas = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        state.params, new_state.params,
    )
    assert max(jax.tree.leaves(deltas)) > 0


def test_loss_decreases_over_steps():
    cfg, shape, state, batch = _setup()
    step_fn = jax.jit(make_train_step(cfg, shape, lr=3e-3))
    losses = []
    for _ in range(8):
        state, m = step_fn(state, batch)  # same batch: must overfit
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_microbatched_grads_match_unbatched():
    cfg, shape1, state, batch = _setup(num_mb=1)
    _, shape4, _, _ = _setup(num_mb=4)
    s1 = jax.jit(make_train_step(cfg, shape1))
    s4 = jax.jit(make_train_step(cfg, shape4))
    n1, m1 = s1(state, batch)
    n4, m4 = s4(state, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(n1.params), jax.tree.leaves(n4.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=5e-4, rtol=5e-3,
        )


def test_moe_arch_train_step_runs():
    cfg, shape, state, batch = _setup("kimi-k2-1t-a32b", num_mb=2)
    step_fn = jax.jit(make_train_step(cfg, shape))
    state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["aux"]) > 0   # router aux loss present


def test_serve_step_greedy_decode_runs():
    cfg = get_arch("granite-3-2b", smoke=True)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    from repro.models.transformer import init_cache

    serve = jax.jit(make_serve_step(cfg), static_argnames=())
    cache = init_cache(cfg, 2, 16)
    tok = jnp.zeros((2,), jnp.int32)
    for t in range(4):
        logits, cache = serve(state.params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    assert tok.shape == (2,)
