import os

# Smoke tests and benches must see the single real CPU device — the 512-way
# placeholder mesh belongs ONLY to repro.launch.dryrun (see its header).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
