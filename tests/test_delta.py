"""Mutable index: delta tier, tombstones, unified fresh+disk search,
compaction equivalence, dirty persistence, and engine write interleaving."""
import json
import os
import threading

import numpy as np
import pytest

from repro.core import (
    DeltaParams,
    MemoryMode,
    MutableIndex,
    MutableVectorIndex,
    PageANNConfig,
    PageANNIndex,
    SearchParams,
    load_index,
    recall_at_k,
)
from repro.core.delta import DeltaTier, scan_delta
from repro.core.search import merge_topk_streams
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors
from repro.serve import BatchingEngine

N, D, Q = 1000, 32, 10
N_BASE = 800

PAD = -1


@pytest.fixture(scope="module")
def dataset():
    x = clustered_vectors(N, D, num_clusters=16, seed=0)
    q = query_vectors(x, Q, seed=1)
    return x, q


def _cfg(**kw):
    base = dict(
        dim=D, graph_degree=12, build_beam=24, pq_subspaces=8,
        lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )
    base.update(kw)
    return PageANNConfig(**base)


@pytest.fixture(scope="module")
def base_index(dataset):
    x, _ = dataset
    return PageANNIndex.build(x[:N_BASE], _cfg())


def _mutable(base_index, **kw):
    kw.setdefault("auto_compact", False)
    return MutableIndex(base_index, **kw)


# -------------------------------------------------------------- delta tier
def test_delta_tier_scan_matches_brute_force():
    rng = np.random.default_rng(0)
    tier = DeltaTier(D, capacity=8)
    vecs = rng.standard_normal((37, D)).astype(np.float32)
    ids = np.arange(100, 137)
    tier.insert(vecs, ids)                     # forces a buffer grow
    q = rng.standard_normal((5, D)).astype(np.float32)

    got_ids, got_d = scan_delta(tier.snapshot(), q, 7)
    d2 = ((q[:, None, :] - vecs[None]) ** 2).sum(-1)
    want = np.argsort(d2, axis=1)[:, :7]
    np.testing.assert_array_equal(got_ids, ids[want])
    np.testing.assert_allclose(
        got_d, np.take_along_axis(d2, want, axis=1), rtol=1e-5, atol=1e-5
    )


def test_delta_tier_upsert_and_kill_semantics():
    tier = DeltaTier(D)
    v = np.eye(D, dtype=np.float32)[:3]
    tier.insert(v, [5, 6, 7])
    assert tier.live_count == 3
    tier.insert(2 * v[:1], [5])                # upsert: old row 5 dies
    assert tier.live_count == 3
    ids, d = scan_delta(tier.snapshot(), 2 * v[:1], 1)
    assert ids[0, 0] == 5 and d[0, 0] == 0.0
    assert tier.kill([6, 99]) == 1             # unknown ids ignored
    assert tier.live_count == 2
    ids, _ = scan_delta(tier.snapshot(), v[1:2], 3)
    assert 6 not in ids
    with pytest.raises(ValueError, match="duplicate"):
        tier.insert(v[:2], [8, 8])


def test_snapshot_is_isolated_from_later_writes():
    tier = DeltaTier(D)
    rng = np.random.default_rng(1)
    v1 = rng.standard_normal((4, D)).astype(np.float32)
    tier.insert(v1, np.arange(4))
    snap = tier.snapshot()
    tier.insert(rng.standard_normal((30, D)).astype(np.float32),
                np.arange(100, 130))
    tier.kill([0, 1, 2, 3])
    q = v1[:1]
    ids, d = scan_delta(snap, q, 4)            # old snapshot: old contents
    assert set(ids[0].tolist()) == {0, 1, 2, 3}
    assert d[0, 0] == 0.0


def test_merge_topk_streams_interleaves_and_masks_pad():
    ids_a = np.array([[0, 1, PAD]], np.int32)
    d_a = np.array([[0.1, 0.5, np.inf]], np.float32)
    ids_b = np.array([[10, 11]], np.int32)
    d_b = np.array([[0.2, np.inf]], np.float32)
    ids, d = merge_topk_streams(ids_a, d_a, ids_b, d_b, k=4)
    np.testing.assert_array_equal(np.asarray(ids), [[0, 10, 1, PAD]])
    assert not np.isfinite(np.asarray(d)[0, 3])


# ---------------------------------------------------------- unified search
def test_pure_read_path_is_bitwise_base(dataset, base_index):
    """No writes yet: the wrapper returns the base result object untouched
    — zero overhead and exact parity on the read-only path."""
    _, q = dataset
    m = _mutable(base_index)
    want = base_index.search(q, k=10)
    got = m.search(q, k=10)
    for f in want._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(want, f)), np.asarray(getattr(got, f)),
            err_msg=f,
        )


def test_mutable_recall_matches_static_over_merged_set(dataset, base_index):
    """Acceptance bar: search over (base ∪ inserts − deletes) reaches at
    least the recall a static build achieves on the same data."""
    x, q = dataset
    m = _mutable(base_index)
    m.insert(x[N_BASE:], ids=np.arange(N_BASE, N))
    deleted = np.arange(0, 40)
    m.delete(deleted)

    live = np.ones(N, bool)
    live[deleted] = False
    live_rows = np.nonzero(live)[0]
    truth = live_rows[brute_force_knn(x[live_rows], q, 10)]

    static = PageANNIndex.build(x[live_rows], _cfg())
    static_ids = live_rows[
        np.maximum(np.asarray(static.search(q, k=10).ids), 0)
    ]
    r_static = recall_at_k(static_ids, truth)

    res = m.search(q, k=10)
    r_mut = recall_at_k(np.asarray(res.ids), truth)
    assert r_mut >= r_static - 1e-9, (r_mut, r_static)
    # tombstoned and never-inserted ids are absent
    assert not np.isin(np.asarray(res.ids), deleted).any()
    # delta hits cost no page reads: ios bounded by a pure base search
    base_only = base_index.search(q, k=10)
    assert np.asarray(res.ios).mean() <= np.asarray(base_only.ios).mean() * 2


def test_delete_heavy_results_stay_full_and_live(dataset, base_index):
    _, q = dataset
    m = _mutable(base_index)
    deleted = np.arange(0, 120)                 # > one oversample bucket
    assert m.delete(deleted) == 120
    assert m.delete(deleted) == 0               # idempotent
    res = m.search(q, k=10)
    ids = np.asarray(res.ids)
    assert (ids >= 0).all()                     # never fewer than k live
    assert not np.isin(ids, deleted).any()
    assert np.isfinite(np.asarray(res.dists)).all()


def test_upsert_moves_vector(dataset, base_index):
    x, _ = dataset
    m = _mutable(base_index)
    far = np.full((1, D), 37.0, np.float32)
    m.insert(far, ids=np.array([123]))
    hit = m.search(far, k=1)
    assert np.asarray(hit.ids)[0, 0] == 123
    # the id's old location no longer resolves to it
    old = m.search(x[123][None], k=5)
    row = np.asarray(old.ids)[0]
    assert 123 not in row


def test_search_params_and_k_resolution(dataset, base_index):
    x, q = dataset
    m = _mutable(base_index)
    m.insert(x[N_BASE:])
    p = SearchParams(k=7, beam_width=32, lsh_entries=8, max_hops=48)
    res = m.search(q, params=p)
    assert np.asarray(res.ids).shape == (Q, 7)
    res5 = m.search(q, k=5, params=p)
    assert np.asarray(res5.ids).shape == (Q, 5)


def test_mutable_implements_protocols(base_index):
    m = _mutable(base_index)
    assert isinstance(m, MutableVectorIndex)
    assert m.dim == D


# -------------------------------------------------------------- compaction
def test_compact_equivalent_to_fresh_build(dataset, base_index):
    """After compact(), results are EQUIVALENT to a cold
    ``PageANNIndex.build`` over the merged dataset — same pipeline, same
    config, same row order, bit-identical outputs."""
    x, q = dataset
    m = _mutable(base_index)
    m.insert(x[N_BASE:], ids=np.arange(N_BASE, N))
    deleted = np.arange(10, 60)
    m.delete(deleted)
    assert m.compact()
    assert m.generation == 1
    assert not m.compact()                      # nothing left to fold
    assert m.stats.tombstones == 0 and m.stats.delta_live == 0

    live = np.ones(N, bool)
    live[deleted] = False
    live_rows = np.nonzero(live)[0]
    fresh = PageANNIndex.build(x[live_rows], _cfg())

    got = m.search(q, k=10)
    want = fresh.search(q, k=10)
    want_ext = np.where(
        np.asarray(want.ids) >= 0,
        live_rows[np.maximum(np.asarray(want.ids), 0)],
        PAD,
    )
    np.testing.assert_array_equal(np.asarray(got.ids), want_ext)
    for f in ("dists", "ios", "hops", "cache_hits"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(want, f)),
            err_msg=f,
        )


def test_auto_compact_triggers_on_fraction(dataset, base_index):
    x, _ = dataset
    m = MutableIndex(
        base_index,
        params=DeltaParams(compact_fraction=0.1),
        auto_compact=True,
    )
    m.insert(x[N_BASE:N_BASE + 40])             # 40/800 = 5%: below
    assert m.generation == 0
    m.insert(x[N_BASE + 40:N_BASE + 120])       # 120/800 = 15%: fires
    assert m.generation == 1
    assert m.stats.delta_live == 0
    assert m.stats.base_rows == N_BASE + 120


# -------------------------------------------------------------- lifecycle
def test_dirty_save_load_bit_identical(tmp_path, dataset, base_index):
    """Acceptance bar: a dirty (uncompacted) index round-trips through
    save/load to bit-identical search results — a restarted server loses
    no inserts and no tombstones."""
    x, q = dataset
    m = _mutable(base_index)
    m.insert(x[N_BASE:N_BASE + 150], ids=np.arange(N_BASE, N_BASE + 150))
    m.delete(np.arange(0, 25))
    m.insert(x[N_BASE + 150:], ids=np.arange(N_BASE + 150, N))
    m.delete([N_BASE + 3, N_BASE + 170])        # delta rows die too

    art = str(tmp_path / "idx.mutable")
    m.save(art)
    loaded = load_index(art)
    assert type(loaded) is MutableIndex
    assert loaded.generation == 0
    assert loaded.stats.tombstones == m.stats.tombstones
    assert loaded.stats.delta_live == m.stats.delta_live

    want = m.search(q, k=10)
    got = loaded.search(q, k=10)
    for f in want._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(want, f)), np.asarray(getattr(got, f)),
            err_msg=f,
        )
    # and the reloaded index keeps taking writes
    loaded.insert(np.full((1, D), 9.0, np.float32))


def test_compact_swaps_persisted_artifact_atomically(
    tmp_path, dataset, base_index
):
    x, q = dataset
    m = _mutable(base_index)
    m.insert(x[N_BASE:N_BASE + 100], ids=np.arange(N_BASE, N_BASE + 100))
    art = str(tmp_path / "idx.mutable")
    m.save(art)

    with open(os.path.join(art, "manifest.json")) as f:
        assert json.load(f)["generation"] == 0
    assert m.compact()
    # manifest generation counter advanced on disk, atomically
    with open(os.path.join(art, "manifest.json")) as f:
        doc = json.load(f)
    assert doc["generation"] == 1
    assert doc["delta_rows"] == 0 and doc["tombstones"] == 0
    # no half-swapped leftovers
    leftovers = [
        p for p in os.listdir(tmp_path) if ".tmp" in p or ".old" in p
    ]
    assert leftovers == []

    reloaded = load_index(art)
    want = m.search(q, k=10)
    got = reloaded.search(q, k=10)
    for f in want._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(want, f)), np.asarray(getattr(got, f)),
            err_msg=f,
        )


# ------------------------------------------------------------- engine I/O
def test_engine_insert_delete_requests(dataset, base_index):
    x, q = dataset
    m = _mutable(base_index)
    eng = BatchingEngine.from_index(m, k=5, batch_size=4)
    ids = eng.insert(x[N_BASE:N_BASE + 20])
    assert ids.shape == (20,)
    rows = eng.search(x[N_BASE:N_BASE + 4], k=1)
    found = np.array([r.result.ids[0] for r in rows])
    np.testing.assert_array_equal(found, ids[:4])
    assert eng.delete(ids[:4]) == 4
    rows = eng.search(x[N_BASE:N_BASE + 4], k=1)
    assert not np.isin(
        np.array([r.result.ids[0] for r in rows]), ids[:4]
    ).any()
    assert eng.compact()
    metrics = eng.metrics()
    assert metrics.inserts == 20
    assert metrics.deletes == 4
    assert metrics.compactions == 1
    eng.close()


def test_engine_rejects_writes_on_immutable_backend(base_index):
    eng = BatchingEngine.from_index(base_index, k=5, batch_size=4)
    with pytest.raises(RuntimeError, match="insert"):
        eng.insert(np.zeros((1, D), np.float32))
    with pytest.raises(RuntimeError, match="delete"):
        eng.delete([0])
    eng.close()


def test_searches_across_compaction_all_complete(dataset, base_index):
    """Satellite acceptance: searches issued concurrently with compact()
    must all complete and never observe a half-swapped artifact — every
    result is a fully consistent top-k from either the old or new state."""
    x, q = dataset
    m = _mutable(base_index)
    m.insert(x[N_BASE:], ids=np.arange(N_BASE, N))
    eng = BatchingEngine.from_index(m, k=5, batch_size=2, timeout_ms=5.0)

    errors = []
    results = []
    stop = threading.Event()

    def searcher(seed):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            row = x[rng.integers(0, N)]
            try:
                r = eng.submit(row).result(timeout=60)
                results.append(np.asarray(r.result.ids))
            except Exception as e:      # noqa: BLE001 — collected for assert
                errors.append(e)

    threads = [threading.Thread(target=searcher, args=(s,)) for s in range(3)]
    for t in threads:
        t.start()
    try:
        for gen in (1, 2):
            m.insert(
                np.full((2, D), 50.0 + gen, np.float32),
                ids=np.array([5000 + 2 * gen, 5001 + 2 * gen]),
            )
            assert m.compact()
            assert m.generation == gen
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=60)
        eng.close()

    assert not errors, errors
    assert len(results) > 0
    universe = set(range(N)) | {5002, 5003, 5004, 5005}
    for ids in results:
        finite = ids[ids >= 0]
        # ids from a torn state would fall outside every generation's set
        assert set(finite.tolist()) <= universe
        assert len(set(finite.tolist())) == len(finite)   # no dup rows
