"""Out-of-HBM streaming page tier: MemoryBudget loads, bit-identity vs
fully resident search, the host fetcher, and the serving-surface plumbing
(stats split, engine metrics, database loads)."""
import json
import os

import numpy as np
import pytest

from repro.core import (
    MemoryBudget,
    MemoryMode,
    MutableIndex,
    PageANNConfig,
    PageANNIndex,
    SearchParams,
    load_index,
)
from repro.core import baselines as bl
from repro.core import persist
from repro.core import stream as stream_mod
from repro.core.vamana import brute_force_knn
from repro.data.pipeline import clustered_vectors, query_vectors

N, D, Q = 1200, 32, 12


@pytest.fixture(scope="module")
def dataset():
    x = clustered_vectors(N, D, num_clusters=16, seed=0)
    q = query_vectors(x, Q, seed=1)
    truth = brute_force_knn(x, q, 10)
    return x, q, truth


def _cfg(**kw):
    base = dict(
        dim=D, graph_degree=12, build_beam=24, pq_subspaces=8,
        lsh_sample=256, lsh_entries=8, beam_width=48, max_hops=48,
        memory_mode=MemoryMode.HYBRID,
    )
    base.update(kw)
    return PageANNConfig(**base)


@pytest.fixture(scope="module", params=list(MemoryMode), ids=lambda m: m.value)
def mode_artifact(request, dataset, tmp_path_factory):
    """One saved artifact per MemoryMode, warmed so page_order carries
    real access counts — what a budgeted load pins its residents by."""
    x, q, _ = dataset
    idx = PageANNIndex.build(x, _cfg(memory_mode=request.param))
    idx.warm_cache(np.asarray(q), params=SearchParams.from_config(idx.cfg))
    art = str(tmp_path_factory.mktemp("stream") / f"idx.{request.param.value}")
    idx.save(art)
    return art


@pytest.fixture(scope="module")
def hybrid_artifact(dataset, tmp_path_factory):
    x, q, _ = dataset
    idx = PageANNIndex.build(x, _cfg())
    idx.warm_cache(np.asarray(q), params=SearchParams.from_config(idx.cfg))
    art = str(tmp_path_factory.mktemp("stream_hy") / "idx.pageann")
    idx.save(art)
    return art


# ----------------------------------------------------------- MemoryBudget
def test_memory_budget_validation():
    with pytest.raises(ValueError, match="exactly one"):
        MemoryBudget()
    with pytest.raises(ValueError, match="exactly one"):
        MemoryBudget(bytes=1 << 20, fraction=0.5)
    with pytest.raises(ValueError, match="positive"):
        MemoryBudget(bytes=0)
    with pytest.raises(ValueError):
        MemoryBudget(bytes=2.5)
    for bad in (0.0, -0.25, 1.5):
        with pytest.raises(ValueError, match="fraction"):
            MemoryBudget(fraction=bad)
    # frozen + hashable: usable as a static jit closure component
    assert hash(MemoryBudget(fraction=0.5)) == hash(MemoryBudget(fraction=0.5))


def test_memory_budget_parse():
    assert MemoryBudget.parse("512MB") == MemoryBudget(bytes=512 * 10**6)
    assert MemoryBudget.parse("1GiB") == MemoryBudget(bytes=1 << 30)
    assert MemoryBudget.parse("0.25") == MemoryBudget(fraction=0.25)
    assert MemoryBudget.parse(0.25) == MemoryBudget(fraction=0.25)
    assert MemoryBudget.parse(4096) == MemoryBudget(bytes=4096)
    b = MemoryBudget(fraction=0.5)
    assert MemoryBudget.parse(b) is b
    with pytest.raises(ValueError):
        MemoryBudget.parse(True)
    with pytest.raises(ValueError):
        MemoryBudget.parse("lots")


def test_memory_budget_resolve_pages():
    assert MemoryBudget(fraction=0.25).resolve_pages(40, 4096) == 10
    assert MemoryBudget(fraction=1.0).resolve_pages(40, 4096) == 40
    # floors, clamps to [1, num_pages]
    assert MemoryBudget(fraction=0.26).resolve_pages(40, 4096) == 10
    assert MemoryBudget(fraction=0.001).resolve_pages(40, 4096) == 1
    assert MemoryBudget(bytes=3 * 4096).resolve_pages(40, 4096) == 3
    assert MemoryBudget(bytes=10**12).resolve_pages(40, 4096) == 40


def test_memory_budget_json_round_trip():
    for b in (MemoryBudget(fraction=0.25), MemoryBudget(bytes=1 << 20)):
        assert MemoryBudget.from_json(json.loads(json.dumps(b.to_json()))) == b


# ----------------------------------------------- bit-identity vs resident
def test_streamed_search_bit_identical_every_mode(dataset, mode_artifact):
    """The tentpole acceptance bar: a load under a 0.25x budget (~4x more
    pages on disk than resident) returns bit-identical
    ids/dists/ios/hops/cache_hits on every MemoryMode."""
    _, q, _ = dataset
    full = PageANNIndex.load(mode_artifact)
    streamed = PageANNIndex.load(
        mode_artifact, memory_budget=MemoryBudget(fraction=0.25)
    )
    assert streamed.fetcher is not None
    assert streamed.stats.resident_pages * 4 <= streamed.stats.pages

    want = full.search(q, k=10)
    got = streamed.search(q, k=10)
    for field in want._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(want, field)),
            np.asarray(getattr(got, field)),
            err_msg=field,
        )
    fs = streamed.fetch_stats()
    assert fs["pages_fetched"] > 0          # the streaming path really ran
    assert full.fetch_stats() == dict(
        pages_fetched=0, fetch_hits=0, fetch_wall_s=0.0
    )


def test_byte_budget_pins_exact_page_count(dataset, hybrid_artifact):
    _, q, _ = dataset
    with open(os.path.join(hybrid_artifact, "manifest.json")) as f:
        rec_bytes = json.load(f)["page_record_bytes"]
    idx = PageANNIndex.load(
        hybrid_artifact, memory_budget=MemoryBudget(bytes=5 * rec_bytes)
    )
    assert idx.stats.resident_pages == 5
    assert idx.stats.resident_bytes == 5 * rec_bytes
    full = PageANNIndex.load(hybrid_artifact)
    np.testing.assert_array_equal(
        idx.search(q, k=10).ids, full.search(q, k=10).ids
    )


def test_budget_covering_whole_file_is_fully_resident(dataset, hybrid_artifact):
    """A budget that fits every page degenerates to the plain resident
    load: no fetcher, no streaming executable, identical stats."""
    idx = PageANNIndex.load(
        hybrid_artifact, memory_budget=MemoryBudget(fraction=1.0)
    )
    assert idx.fetcher is None
    assert idx.stats.resident_pages == idx.stats.pages
    assert idx.stats.resident_bytes == idx.stats.disk_bytes


# ----------------------------------------------------- stats + manifest
def test_stats_report_resident_streamed_split(hybrid_artifact):
    streamed = PageANNIndex.load(hybrid_artifact, memory_budget=0.25)
    s = streamed.stats
    assert 0 < s.resident_pages < s.pages
    assert 0 < s.resident_bytes < s.disk_bytes
    assert s.resident_bytes == s.resident_pages * streamed.store.padded_tile_bytes()


def test_budget_round_trips_through_manifest(tmp_path, dataset, hybrid_artifact):
    """Re-saving a budgeted index writes the FULL page file (the memmap is
    the source of truth) and records the budget in the manifest's
    residency section; the re-saved artifact reloads at full residency."""
    _, q, _ = dataset
    budget = MemoryBudget(fraction=0.25)
    streamed = PageANNIndex.load(hybrid_artifact, memory_budget=budget)
    art2 = str(tmp_path / "resaved.pageann")
    streamed.save(art2)

    with open(os.path.join(art2, "manifest.json")) as f:
        doc = json.load(f)
    res = doc["residency"]
    assert MemoryBudget.from_json(res["memory_budget"]) == budget
    assert res["resident_pages"] == streamed.stats.resident_pages
    assert res["total_pages"] == streamed.stats.pages
    assert (
        os.path.getsize(os.path.join(art2, "pages.bin"))
        == os.path.getsize(os.path.join(hybrid_artifact, "pages.bin"))
    )
    full = PageANNIndex.load(art2)
    assert full.fetcher is None
    np.testing.assert_array_equal(
        full.search(q, k=10).ids,
        PageANNIndex.load(hybrid_artifact).search(q, k=10).ids,
    )


def test_unbudgeted_manifest_has_null_budget(hybrid_artifact):
    with open(os.path.join(hybrid_artifact, "manifest.json")) as f:
        doc = json.load(f)
    assert doc["residency"]["memory_budget"] is None
    assert (
        doc["residency"]["resident_pages"] == doc["residency"]["total_pages"]
    )


# ------------------------------------------------------------ PageFetcher
def test_fetcher_pad_and_shapes():
    recs = np.arange(4 * 2 * 8, dtype=np.float32).reshape(4, 2, 8)
    f = stream_mod.PageFetcher(recs)
    out = f(np.array([[2, stream_mod.PAD], [0, 3]]))
    assert out.shape == (2, 2, 2, 8)
    np.testing.assert_array_equal(out[0, 0], recs[2])
    np.testing.assert_array_equal(out[0, 1], np.zeros((2, 8), np.float32))
    np.testing.assert_array_equal(out[1, 0], recs[0])
    with pytest.raises(ValueError, match="rows"):
        stream_mod.PageFetcher(np.zeros((4, 8), np.float32))
    with pytest.raises(ValueError, match="stage_pages"):
        stream_mod.PageFetcher(recs, stage_pages=0)


def test_fetcher_lru_eviction_stays_correct():
    """A pathologically tiny staging cache (1 page) changes only the
    hit/miss split, never the returned records."""
    rng = np.random.default_rng(0)
    recs = rng.standard_normal((6, 2, 8)).astype(np.float32)
    f = stream_mod.PageFetcher(recs, stage_pages=1)
    ids = rng.integers(0, 6, size=64)
    for pid in ids:
        np.testing.assert_array_equal(f(np.array([pid]))[0], recs[pid])
    fs = f.fetch_stats()
    assert fs["pages_fetched"] + fs["fetch_hits"] == len(ids)
    assert fs["pages_fetched"] >= 6                   # capacity-1 thrashing
    f.reset_stats()
    assert f.fetch_stats() == dict(
        pages_fetched=0, fetch_hits=0, fetch_wall_s=0.0, wall_window=()
    )


def test_fetcher_counters_accumulate():
    recs = np.zeros((3, 2, 8), np.float32)
    f = stream_mod.PageFetcher(recs)
    f(np.array([0, 1]))
    f(np.array([0, 1, 2]))
    fs = f.fetch_stats()
    assert fs["pages_fetched"] == 3
    assert fs["fetch_hits"] == 2
    assert fs["fetch_wall_s"] >= 0.0


# --------------------------------------------- mutable tier over streaming
def test_churn_workload_matches_resident_base(dataset, hybrid_artifact):
    """A 95/5-style churn mix (insert batches, base-id tombstones, batched
    reads) over a STREAMED base returns exactly what the same mix over the
    fully resident base returns, at every step."""
    x, q, _ = dataset
    rng = np.random.default_rng(7)
    resident = MutableIndex(PageANNIndex.load(hybrid_artifact))
    streamed = MutableIndex(
        PageANNIndex.load(hybrid_artifact, memory_budget=0.25)
    )
    fresh = rng.standard_normal((40, D)).astype(np.float32)
    for step in range(5):
        rows = np.arange(step * 8, step * 8 + 8)
        ids = N + rows
        resident.insert(fresh[rows], ids=ids)
        streamed.insert(fresh[rows], ids=ids)
        victim = rng.integers(0, N, size=2)
        resident.delete(victim)
        streamed.delete(victim)
        want = resident.search(q, k=10)
        got = streamed.search(q, k=10)
        for field in want._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(want, field)),
                np.asarray(getattr(got, field)),
                err_msg=f"step {step}: {field}",
            )
    assert streamed.fetch_stats()["pages_fetched"] > 0
    assert resident.fetch_stats()["pages_fetched"] == 0


def test_mutable_load_accepts_budget(tmp_path, dataset, hybrid_artifact):
    _, q, _ = dataset
    mut = MutableIndex(PageANNIndex.load(hybrid_artifact))
    mut.insert(np.ones((3, D), np.float32), ids=np.arange(N, N + 3))
    art = str(tmp_path / "mut.delta")
    mut.save(art)
    loaded = MutableIndex.load(art, memory_budget=0.25)
    assert loaded.fetch_stats()["pages_fetched"] == 0     # nothing searched yet
    np.testing.assert_array_equal(
        loaded.search(q, k=10).ids, mut.search(q, k=10).ids
    )
    assert loaded.fetch_stats()["pages_fetched"] > 0


# ------------------------------------------------------- serving surface
def test_engine_metrics_report_fetch_counters(dataset, hybrid_artifact):
    from repro.serve import VectorService

    _, q, _ = dataset
    with VectorService(batch_size=4) as svc:
        svc.attach("res", hybrid_artifact)
        svc.attach("str", hybrid_artifact, memory_budget="0.25")
        want = [r.result.ids for r in svc.search("res", q, k=10)]
        got = [r.result.ids for r in svc.search("str", q, k=10)]
        np.testing.assert_array_equal(np.stack(want), np.stack(got))
        m = svc.metrics()
        assert m.pages_fetched > 0
        assert m.fetch_wall_s >= 0.0
        assert m.pages_fetched + m.fetch_hits > 0


def test_streamed_geometry_never_shares_compiled_key(hybrid_artifact):
    """A streamed index's executable closes over its host fetcher — the
    compile cache must key it apart from the resident geometry (and from
    any other streamed load)."""
    from repro.serve.compile_cache import geometry_of

    full = PageANNIndex.load(hybrid_artifact)
    s1 = PageANNIndex.load(hybrid_artifact, memory_budget=0.25)
    s2 = PageANNIndex.load(hybrid_artifact, memory_budget=0.25)
    assert geometry_of(full) != geometry_of(s1)
    assert geometry_of(s1) != geometry_of(s2)
    assert geometry_of(s1) == geometry_of(s1)


def test_database_load_threads_budget(tmp_path, dataset, hybrid_artifact):
    from repro.serve import VectorService

    _, q, _ = dataset
    db = str(tmp_path / "db")
    with VectorService(batch_size=4) as svc:
        svc.attach("wiki", hybrid_artifact)
        svc.save(db)
    with VectorService.load(db, batch_size=4, memory_budget=0.25) as svc:
        idx = svc.index_of("wiki")
        assert idx.fetcher is not None
        assert idx.stats.resident_pages * 4 <= idx.stats.pages
        rows = svc.search("wiki", q, k=10)
        assert len(rows) == Q
        assert svc.metrics().pages_fetched > 0


def test_baselines_reject_memory_budget(tmp_path, dataset):
    x, _, _ = dataset
    idx = bl.StarlingIndex.build(x, _cfg())
    art = str(tmp_path / "idx.starling")
    idx.save(art)
    with pytest.raises(ValueError, match="memory_budget"):
        load_index(art, memory_budget=0.25)
    # no budget still loads fine
    assert type(load_index(art)) is bl.StarlingIndex


def test_load_index_dispatch_streams_pageann(dataset, hybrid_artifact):
    _, q, _ = dataset
    idx = load_index(hybrid_artifact, memory_budget="0.25")
    assert type(idx) is PageANNIndex and idx.fetcher is not None
    np.testing.assert_array_equal(
        idx.search(q, k=10).ids,
        load_index(hybrid_artifact).search(q, k=10).ids,
    )
