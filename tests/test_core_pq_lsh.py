"""PQ + LSH component tests (quality + invariants)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property sweeps skip cleanly without it
from hypothesis import given, settings, strategies as st

from repro.core import lsh as lsh_mod
from repro.core import pq as pq_mod


def test_pq_reconstruction_improves_with_subspaces(rng):
    x = rng.standard_normal((512, 32)).astype(np.float32)
    errs = []
    for m in (2, 8, 16):
        books = pq_mod.train_pq(x, m, ksub=64, iters=8)
        codes = pq_mod.pq_encode(jnp.asarray(x), jnp.asarray(books))
        rec = pq_mod.pq_decode(codes, jnp.asarray(books))
        errs.append(float(np.square(np.asarray(rec) - x).mean()))
    assert errs[0] > errs[1] > errs[2]


def test_adc_approximates_exact_distance(rng):
    x = rng.standard_normal((400, 32)).astype(np.float32)
    q = rng.standard_normal((32,)).astype(np.float32)
    books = pq_mod.train_pq(x, 16, ksub=64, iters=10)
    codes = pq_mod.pq_encode(jnp.asarray(x), jnp.asarray(books))
    lut = pq_mod.pq_lut(jnp.asarray(q), jnp.asarray(books))
    est = np.asarray(pq_mod.adc_distance(codes, lut))
    exact = np.square(x - q).sum(-1)
    # rank correlation must be strong (that's all the search needs)
    top_est = set(np.argsort(est)[:40].tolist())
    top_exact = set(np.argsort(exact)[:40].tolist())
    assert len(top_est & top_exact) >= 20


def test_adc_matches_lut_sum_exactly(rng):
    books = rng.standard_normal((4, 16, 8)).astype(np.float32)
    codes = rng.integers(0, 16, (20, 4)).astype(np.uint8)
    q = rng.standard_normal((32,)).astype(np.float32)
    lut = pq_mod.pq_lut(jnp.asarray(q), jnp.asarray(books))
    got = np.asarray(pq_mod.adc_distance(jnp.asarray(codes), lut))
    want = np.asarray(lut)[np.arange(4)[None], codes.astype(int)].sum(-1)
    np.testing.assert_allclose(got, want, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(bits=st.sampled_from([32, 64]), n=st.integers(2, 64))
def test_pack_bits_hamming_identity(bits, n):
    rng = np.random.default_rng(n)
    raw = rng.integers(0, 2, (n, bits)).astype(np.uint32)
    packed = lsh_mod.pack_bits(jnp.asarray(raw))
    d = lsh_mod.hamming_distance(packed, packed[0])
    want = (raw != raw[0]).sum(-1)
    np.testing.assert_array_equal(np.asarray(d), want)


def test_lsh_routes_to_similar_vectors(rng):
    # clustered data: queries near cluster centers must route to same cluster
    centers = rng.standard_normal((8, 32)).astype(np.float32)
    assign = np.repeat(np.arange(8), 64)
    x = centers[assign] + 0.05 * rng.standard_normal((512, 32)).astype(np.float32)
    codes = np.zeros((512, 4), np.uint8)
    idx = lsh_mod.build_lsh(x, codes, bits=64, sample=512, seed=0)
    hits = 0
    for c in range(8):
        q = centers[c] + 0.05 * rng.standard_normal(32).astype(np.float32)
        ids, _ = idx.query(jnp.asarray(q), top_t=8)
        got = assign[np.asarray(idx.sample_ids)[np.isin(np.asarray(idx.sample_ids), np.asarray(ids))]]
        routed = assign[np.asarray(ids)]
        hits += (routed == c).mean()
    assert hits / 8 > 0.6


def test_lsh_memory_accounting():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((256, 16)).astype(np.float32)
    codes = np.zeros((256, 8), np.uint8)
    idx = lsh_mod.build_lsh(x, codes, bits=32, sample=128)
    assert idx.memory_bytes == 16 * 32 * 4 + 128 * 4 + 128 * 1 * 4 + 128 * 8
